"""Render EXPERIMENTS.md section Dry-run / Roofline tables from the
dry-run JSON records.  Appends (or refreshes) the generated block at the
end of EXPERIMENTS.md."""
import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
MARK = "<!-- AUTOGEN TABLES -->"


def fmt(rec):
    if rec.get("status") == "skipped":
        return (f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"skipped (full-attention; sub-quadratic required) "
                f"| | | | | | |")
    if rec.get("status") != "ok":
        return (f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"ERROR {rec.get('error', '')[:40]} | | | | | | |")
    r = rec["roofline"]
    m = rec.get("memory", {})
    peak = f"{m.get('peak_gib', float('nan')):.1f}"
    fits = {True: "yes", False: "NO"}.get(m.get("fits_16gib"), "?")
    return ("| {arch} | {shape} | {mesh} | ok | {c:.4f} | {b:.3f} | "
            "{l:.4f} | {dom} | {u:.3f} | {peak}/{fits} |".format(
                arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                c=r["compute_s"], b=r["memory_s"], l=r["collective_s"],
                dom=r["dominant"], u=r["useful_ratio"], peak=peak,
                fits=fits))


def main():
    rows = []
    for path in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    sp = [r for r in rows if r["mesh"] == "16x16"]
    mp = [r for r in rows if r["mesh"] == "2x16x16"]
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    lines = [MARK, "", "### Roofline table (single-pod 16x16 = 256 chips)",
             "",
             "compute/memory/collective terms in SECONDS per step; "
             "useful = MODEL_FLOPS / HLO_FLOPs(global); peak GiB/chip "
             "from the scanned memory pass.", "",
             "| arch | shape | mesh | status | compute_s | memory_s | "
             "collective_s | dominant | useful | peak/fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    lines += [fmt(r) for r in sp]
    lines += ["", "### Multi-pod (2x16x16 = 512 chips) — compile proof + "
              "terms", "",
              "| arch | shape | mesh | status | compute_s | memory_s | "
              "collective_s | dominant | useful | peak/fits |",
              "|---|---|---|---|---|---|---|---|---|---|"]
    lines += [fmt(r) for r in mp]
    n_cells = len(sp)
    n_ok = len([r for r in sp if r.get("status") == "ok"])
    lines += ["", f"Recorded cells: {len(rows)} total "
              f"({n_ok}/{n_cells} single-pod compiled ok, "
              f"{len(skipped)} skips = long_500k on full-attention archs "
              f"per the assignment rule).", "",
              "Dominant-term observations: nearly every cell is memory-"
              "dominated under CPU-HLO byte accounting (section 2 caveat); "
              "by the fusion-insensitive terms, training cells are "
              "compute-heavy with 15-40% collective share (FSDP gathers + "
              "TP psums), decode cells are HBM/cache-bound as expected, "
              "and useful-ratio flags exactly two design smells: big-vocab "
              "LM heads at short seq (grok: 131k vocab = 5x model FLOPs "
              "at 4k train) and non-absorbed MLA decode (fixed in the "
              "hillclimb, section 3)."]
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        txt = f.read()
    txt = txt.split(MARK)[0].rstrip() + "\n\n" + "\n".join(lines) + "\n"
    with open(path, "w") as f:
        f.write(txt)
    print(f"rendered {len(rows)} records into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
